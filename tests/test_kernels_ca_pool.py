"""ca_pool kernel vs oracle: shape/pool/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressive import ca_coefficients
from repro.kernels.ca_pool.ops import ca_pool
from repro.kernels.ca_pool.ref import ca_pool_ref, ca_pool_ref_generic


@pytest.mark.parametrize("shape,pool", [
    ((2, 32, 32, 3), 2), ((1, 28, 28, 3), 4), ((3, 16, 24, 3), 2),
    ((1, 64, 64, 3), 8), ((4, 8, 8, 3), 2),
])
def test_matches_compressive_acquire(shape, pool):
    img = jax.random.uniform(jax.random.PRNGKey(shape[1]), shape)
    got = ca_pool(img, pool)
    want = ca_pool_ref(img, pool)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_custom_coefficients():
    img = jax.random.uniform(jax.random.PRNGKey(0), (2, 12, 12, 3))
    coeffs = jax.random.uniform(jax.random.PRNGKey(1), (3, 3, 3))
    got = ca_pool(img, 3, coeffs=coeffs)
    want = ca_pool_ref_generic(img, coeffs, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    img = jax.random.uniform(jax.random.PRNGKey(2), (1, 16, 16, 3)).astype(dtype)
    got = ca_pool(img, 2)
    assert got.dtype == dtype
    want = ca_pool_ref(img.astype(jnp.float32), 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=2e-2, atol=1e-2)


def test_single_channel_pool():
    img = jax.random.uniform(jax.random.PRNGKey(3), (2, 8, 8, 1))
    got = ca_pool(img, 2, rgb_to_gray=True,
                  coeffs=ca_coefficients(2, 1))
    want = img.reshape(2, 4, 2, 4, 2, 1).mean(axis=(2, 4))[..., 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
