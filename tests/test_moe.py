"""MoE sort-based dispatch tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.moe import (MoEConfig, init_moe, moe_ffn, moe_ffn_dense_oracle,
                          moe_ffn_grouped)


def _setup(e=4, k=2, d=16, f=32, cf=8.0, seed=0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_model=d, d_ff=f,
                    capacity_factor=cf)
    params = init_moe(jax.random.PRNGKey(seed), cfg)
    return cfg, params


@pytest.mark.parametrize("e,k", [(4, 2), (8, 2), (16, 4), (8, 1)])
def test_matches_dense_oracle_with_headroom(e, k):
    """With generous capacity nothing drops -> must equal the oracle."""
    cfg, params = _setup(e=e, k=k, cf=float(e))      # huge capacity
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16))
    out = moe_ffn(params, x, cfg)
    want = moe_ffn_dense_oracle(params, x, cfg)
    assert float(out.dropped_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens():
    cfg, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, 16))
    out = moe_ffn(params, x, cfg, capacity=2)        # absurdly tight
    assert float(out.dropped_fraction) > 0.0
    assert bool(jnp.all(jnp.isfinite(out.y)))


def test_aux_losses():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16))
    out = moe_ffn(params, x, cfg)
    assert float(out.balance_loss) > 0.0             # ~coef when balanced
    assert float(out.z_loss) >= 0.0
    # perfectly balanced router would give balance ~= coef * 1.0
    assert float(out.balance_loss) < cfg.balance_coef * cfg.n_experts


def test_deterministic():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, 16))
    y1 = moe_ffn(params, x, cfg).y
    y2 = moe_ffn(params, x, cfg).y
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_gradients_flow():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 16))

    def loss(p):
        out = moe_ffn(p, x, cfg)
        return jnp.sum(out.y ** 2) + out.balance_loss + out.z_loss

    g = jax.grad(loss)(params)
    gnorms = {k: float(jnp.linalg.norm(v.reshape(-1)))
              for k, v in g.items()}
    assert gnorms["w_gate"] > 0 and gnorms["w_down"] > 0
    assert gnorms["router"] > 0                      # via combine weights


def test_jit_and_shapes():
    cfg, params = _setup(e=8, k=2)
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 8, 16))
    f = jax.jit(lambda p, x: moe_ffn(p, x, cfg).y)
    y = f(params, x)
    assert y.shape == x.shape


@pytest.mark.parametrize("e,k", [(4, 2), (8, 2)])
def test_grouped_matches_dense_oracle(e, k):
    """The §Perf grouped dispatch is numerically identical to the oracle
    when per-group capacity has headroom."""
    cfg, params = _setup(e=e, k=k, cf=float(e))
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 16, 16))
    out = moe_ffn_grouped(params, x, cfg)
    want = moe_ffn_dense_oracle(params, x, cfg)
    assert float(out.dropped_fraction) == 0.0
    np.testing.assert_allclose(np.asarray(out.y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_grouped_capacity_is_per_row():
    cfg, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 32, 16))
    out = moe_ffn_grouped(params, x, cfg, capacity=2)
    assert float(out.dropped_fraction) > 0.0
    assert bool(jnp.all(jnp.isfinite(out.y)))


def test_grouped_gradients_flow():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, 16))

    def loss(p):
        out = moe_ffn_grouped(p, x, cfg)
        return jnp.sum(out.y ** 2) + out.balance_loss

    g = jax.grad(loss)(params)
    assert float(jnp.linalg.norm(g["w_down"].reshape(-1))) > 0
    assert float(jnp.linalg.norm(g["router"].reshape(-1))) > 0
