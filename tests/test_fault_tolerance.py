"""Fault tolerance: failure injection + restart continuation, stragglers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import CheckpointManager
from repro.distributed.fault_tolerance import (FailureInjector,
                                               RestartableLoop,
                                               StragglerMonitor)


def _make_loop(tmp_path, fail_at=None, interval=2):
    """A deterministic toy training loop: state['x'] += mean(batch)."""

    def step_fn(state, batch):
        x = state["x"] + jnp.mean(batch["v"])
        return {"x": x, "step": state["step"] + 1}, {"x": float(x)}

    def batch_fn(step):
        rng = np.random.default_rng(step)     # pure function of step
        return {"v": jnp.asarray(rng.standard_normal(4), jnp.float32)}

    ckpt = CheckpointManager(tmp_path, keep=3, save_interval_steps=interval)
    return RestartableLoop(step_fn, batch_fn, ckpt,
                           injector=FailureInjector(fail_at)), step_fn, batch_fn


def test_crash_and_restart_bit_exact(tmp_path):
    state0 = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}

    # uninterrupted reference run
    ref_loop, _, _ = _make_loop(tmp_path / "ref")
    ref_state, _, _ = ref_loop.run(dict(state0), 0, 10)

    # crashing run: fails at step 7 (last complete ckpt at step 6)
    loop, _, _ = _make_loop(tmp_path / "crash", fail_at=7)
    with pytest.raises(RuntimeError, match="injected failure"):
        loop.run(dict(state0), 0, 10)

    # restart: resumes from the checkpoint, replays deterministically
    loop2, _, _ = _make_loop(tmp_path / "crash")
    final, last, _ = loop2.run(dict(state0), 0, 10)
    assert last == 10
    np.testing.assert_allclose(float(final["x"]), float(ref_state["x"]),
                               rtol=1e-6)


def test_restart_skips_completed_work(tmp_path):
    state0 = {"x": jnp.zeros(()), "step": jnp.zeros((), jnp.int32)}
    loop, _, _ = _make_loop(tmp_path)
    loop.run(dict(state0), 0, 10)
    # a fresh loop over the same dir should do zero extra steps
    loop2, _, _ = _make_loop(tmp_path)
    _, last, history = loop2.run(dict(state0), 0, 10)
    assert last == 10 and history == []


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=2)
    for i in range(8):
        mon.record(i, 0.1)
    ev = mon.record(8, 0.5)                       # 5x the EWMA
    assert ev is not None and ev.ratio > 2.0
    assert len(mon.events) == 1
    # outlier must not poison the EWMA
    assert mon.ewma == pytest.approx(0.1, rel=1e-6)


def test_straggler_callback():
    hits = []
    mon = StragglerMonitor(threshold=1.5, warmup_steps=1,
                           on_straggler=hits.append)
    mon.record(0, 0.1)
    mon.record(1, 0.1)
    mon.record(2, 1.0)
    assert len(hits) == 1 and hits[0].step == 2


def test_injector_fires_once():
    inj = FailureInjector(3)
    inj.maybe_fail(2)
    with pytest.raises(RuntimeError):
        inj.maybe_fail(3)
    inj.maybe_fail(3)                              # second pass: no raise
