"""The device pool (``repro.serve.pool``): placement, stealing, faults,
and the bit-identity property across virtual devices.

Three tiers:

* **Thread-free units** — the placement policies and the zero-copy result
  split, pure objects exercised without a pool.
* **Pool-level tests on any machine** — work stealing, fault injection
  and stats run against a 2-worker pool whose device work is replaced by
  the injectable execute hook, so they need no multi-device jax at all.
* **The property suite** — random programs x batch sizes x bucket
  ladders routed across a real 4-virtual-device pool must be **bitwise**
  equal to direct single-device ``run_per_frame``. These tests skip
  unless 4 local devices exist; the CI leg runs them in a subprocess
  with ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
  (``scripts/ci.sh``), since the device count is fixed at jax init.

Why bit-identity holds: every worker runs the same per-frame-calibrated
executor on a device-bound view of one compiled plan, and per-frame
calibration makes each frame's result a pure function of that frame —
so placement, stealing, padding and batch composition cannot perturb it.
"""

import queue
import threading
import types
import warnings

import jax
import numpy as np
import pytest

import repro
from repro import serve
from repro.core.quant import W4A4
from repro.serve import batcher, pool as pool_mod

REFERENCE = repro.Options(scheme=W4A4, backend="reference")
N_DEVICES = len(jax.local_devices())

needs4 = pytest.mark.skipif(
    N_DEVICES < 4,
    reason="needs 4 local devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def lenet_exe():
    prog = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    return prog, prog.compile(REFERENCE)


@pytest.fixture(scope="module")
def frames28():
    rng = np.random.default_rng(0)
    return rng.random((9, 28, 28, 1)).astype(np.float32)


# -- placement policies (thread-free units) -----------------------------------

def test_least_loaded_picks_minimum_and_rotates_ties():
    p = serve.LeastLoaded()
    # strictly-lower load always wins
    assert p.choose([5, 2, 7]) == 1
    assert p.choose([0, 9, 9]) == 0
    # all-idle ties rotate: consecutive batches spread across devices
    # instead of hammering device 0
    q = serve.LeastLoaded()
    assert [q.choose([0, 0, 0, 0]) for _ in range(8)] == [0, 1, 2, 3] * 2


def test_round_robin_ignores_load():
    p = serve.RoundRobin()
    assert [p.choose([9, 0, 0]) for _ in range(4)] == [0, 1, 2, 0]


def test_placement_registry_and_config_validation():
    assert set(serve.PLACEMENTS) == {"least_loaded", "round_robin"}
    with pytest.raises(ValueError, match="unknown placement"):
        serve.ServeConfig(placement="bogus")
    with pytest.raises(ValueError, match="devices"):
        serve.ServeConfig(devices=0)
    with pytest.raises(ValueError, match="device"):
        pool_mod.Pool(0, serve.RoundRobin(), queue.Queue())


def test_split_results_returns_zero_copy_views():
    """The per-request result split must not copy: each part is a view
    into the batch output array (the host-side perf contract)."""
    out = np.arange(24, dtype=np.float32).reshape(6, 4)
    parts = batcher.split_results(out, [1, 2, 3])
    assert [p.shape[0] for p in parts] == [1, 2, 3]
    assert all(np.shares_memory(p, out) for p in parts)


# -- pool mechanics via the execute hook (no multi-device jax needed) ---------

def _hosted_stub(name="p", n_devices=2):
    # the execute hook replaces the device call, so bound exes are unused
    return types.SimpleNamespace(name=name, bound=(None,) * n_devices)


def _batch(hosted, fill, n=2):
    frames = np.full((n, 2, 2, 1), fill, np.float32)
    return pool_mod.Batch(hosted, [], frames, n, n, 0.0)


def test_pool_work_stealing_drains_a_blocked_devices_backlog():
    """Pin every placement to device 0, block the worker that grabs the
    first batch: the idle peer must steal the second instead of letting
    it strand behind the blocked device."""
    done: queue.Queue = queue.Queue()
    gate = threading.Event()
    started = threading.Event()

    def execute(program, device, frames, bucket, default):
        if frames[0, 0, 0, 0] == 1.0:       # first batch: hold the device
            started.set()
            assert gate.wait(30)
        return frames * 2.0

    class PinZero:
        def choose(self, loads):
            return 0

    pool = pool_mod.Pool(2, PinZero(), done, execute_hook=execute, pipeline=1)
    pool.start()
    hosted = _hosted_stub()
    try:
        pool.dispatch(_batch(hosted, 1.0))
        assert started.wait(30)             # batch 1 holds one worker
        pool.dispatch(_batch(hosted, 2.0))  # also queued on device 0
        first = done.get(timeout=30)        # ...but finishes on the peer
        assert first.error is None
        np.testing.assert_array_equal(first.out,
                                      np.full((2, 2, 2, 1), 4.0, np.float32))
        gate.set()
        second = done.get(timeout=30)
        assert second.error is None
        np.testing.assert_array_equal(second.out,
                                      np.full((2, 2, 2, 1), 2.0, np.float32))
    finally:
        gate.set()
        pool.stop(timeout=30)
    st = pool.stats()
    assert st["steals"] == 1                # exactly one batch changed hands
    assert sum(d["batches"] for d in st["per_device"]) == 2
    assert sum(d["steals"] for d in st["per_device"]) == 1
    assert {first.device, second.device} == {0, 1}
    assert all(d["queued_frames"] == 0 and d["inflight_frames"] == 0
               for d in st["per_device"])
    assert st["placement_us"]["count"] == 2


def test_pool_fault_isolated_to_one_batch():
    """A raising execute hook fails exactly its batch with a typed
    WorkerError (original exception chained); the worker and the pool
    keep serving."""
    done: queue.Queue = queue.Queue()

    def execute(program, device, frames, bucket, default):
        if frames[0, 0, 0, 0] == 13.0:
            raise RuntimeError("kaboom")
        return frames + 1.0

    pool = pool_mod.Pool(2, serve.RoundRobin(), done, execute_hook=execute,
                         pipeline=2)
    pool.start()
    hosted = _hosted_stub()
    try:
        pool.dispatch(_batch(hosted, 13.0))
        pool.dispatch(_batch(hosted, 5.0))
        results = [done.get(timeout=30) for _ in range(2)]
    finally:
        pool.stop(timeout=30)
    failed = [d for d in results if d.error is not None]
    ok = [d for d in results if d.error is None]
    assert len(failed) == 1 and len(ok) == 1
    err = failed[0].error
    assert isinstance(err, serve.WorkerError)
    assert err.program == "p" and err.device == failed[0].device
    assert isinstance(err.__cause__, RuntimeError)
    assert "kaboom" in str(err.__cause__)
    np.testing.assert_array_equal(ok[0].out,
                                  np.full((2, 2, 2, 1), 6.0, np.float32))
    st = pool.stats()
    assert sum(d["failures"] for d in st["per_device"]) == 1
    assert all(d["inflight_frames"] == 0 for d in st["per_device"])


def test_pool_stop_flushes_pending_completions():
    """Pool.stop must put every dispatched batch's completion on the done
    queue before returning — the guarantee that lets the server sentinel
    its completer without stranding futures."""
    done: queue.Queue = queue.Queue()
    pool = pool_mod.Pool(2, serve.LeastLoaded(), done,
                         execute_hook=lambda *a: a[2] * 3.0, pipeline=2)
    pool.start()
    hosted = _hosted_stub()
    for i in range(8):
        pool.dispatch(_batch(hosted, float(i)))
    pool.stop(timeout=30)
    assert done.qsize() == 8
    while not done.empty():
        assert done.get().error is None


def test_pool_take_outstanding_reclaims_wedged_work():
    """A finite Pool.stop(timeout) can expire with a wedged worker still
    holding work: alive() must report it and take_outstanding() must
    hand back both the queued batch (removed, never executable) and the
    in-flight one (snapshot), so the server can fail their futures
    instead of stranding them."""
    done: queue.Queue = queue.Queue()
    gate = threading.Event()
    entered = threading.Event()

    def execute(program, device, frames, bucket, default):
        entered.set()
        assert gate.wait(30)            # wedge the only worker
        return frames

    pool = pool_mod.Pool(1, serve.RoundRobin(), done,
                         execute_hook=execute, pipeline=1)
    pool.start()
    hosted = _hosted_stub(n_devices=1)
    b1, b2 = _batch(hosted, 1.0), _batch(hosted, 2.0)
    try:
        pool.dispatch(b1)
        assert entered.wait(30)
        pool.dispatch(b2)               # stuck behind the wedged batch
        pool.stop(timeout=0.2)
        assert pool.alive()
        queued, inflight = pool.take_outstanding()
        assert queued == [b2] and inflight == [b1]   # identity (eq=False)
        st = pool.stats()
        assert st["per_device"][0]["queued_frames"] == 0
        # idempotent: a second reclaim finds no queued work
        assert pool.take_outstanding()[0] == []
    finally:
        gate.set()                      # release the worker; full join
        pool.stop(timeout=30)
    assert not pool.alive()
    assert done.get(timeout=30).error is None        # b1 still completed


# -- server-level fault injection ---------------------------------------------

def test_server_fault_injection_fails_only_that_batch(lenet_exe, frames28):
    """Satellite: a device worker raising mid-batch fails only that
    batch's requests with a typed error; the pool drains cleanly, the
    completer never deadlocks, and Server.stats() records the failure."""
    prog, exe = lenet_exe
    fired = []

    def execute(program, device, frames, bucket, default):
        if not fired:                       # first batch only
            fired.append((program, device))
            raise RuntimeError("injected device fault")
        return default()

    server = serve.Server(
        serve.ServeConfig(max_batch=4, max_wait_ms=0.0),
        hooks=serve.Hooks(execute=execute))
    server.register("lenet", prog, REFERENCE)
    server.start()
    try:
        doomed = server.submit("lenet", frames28[:2])
        with pytest.raises(serve.WorkerError) as ei:
            doomed.result(timeout=120)
        assert ei.value.program == "lenet"
        assert ei.value.device == fired[0][1]
        assert isinstance(ei.value.__cause__, RuntimeError)
        # the pool keeps serving — bit-identically — after the fault
        ok = server.submit("lenet", frames28[2:4]).result(timeout=120)
        np.testing.assert_array_equal(
            ok, np.asarray(exe.run_per_frame(frames28[2:4])))
        st = server.stats()
        assert st["programs"]["lenet"]["requests"]["failed"] == 1
        assert st["programs"]["lenet"]["requests"]["served"] == 1
        assert sum(d["failures"] for d in st["pool"]["per_device"]) == 1
    finally:
        server.stop()                       # drains without deadlocking
    assert server.stats()["queue_depth"] == 0


def test_stop_timeout_fails_stranded_batches_instead_of_hanging(lenet_exe,
                                                                frames28):
    """Server.stop(timeout=...) expiring with a wedged device worker
    must fail that batch's futures with ServerClosed — not sentinel the
    completer past them and leave result() blocking forever."""
    prog, _ = lenet_exe
    gate = threading.Event()
    entered = threading.Event()

    def execute(program, device, frames, bucket, default):
        entered.set()
        assert gate.wait(30)            # wedge the device worker
        return default()

    server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.0),
                          hooks=serve.Hooks(execute=execute))
    server.register("lenet", prog, REFERENCE)
    server.start()
    try:
        fut = server.submit("lenet", frames28[:2])
        assert entered.wait(30)
        server.stop(drain=False, timeout=0.2)
        with pytest.raises(serve.ServerClosed, match="outstanding"):
            fut.result(timeout=30)
        st = server.stats()
        assert st["programs"]["lenet"]["requests"]["failed"] == 1
        assert st["queue_depth"] == 0
    finally:
        gate.set()   # release the wedged worker thread; its late
        # completion must be a silent no-op on the already-failed future


# -- device binding (single device is enough) ---------------------------------

def test_bind_device_bit_identical_and_staging_reused(lenet_exe, frames28):
    _, exe = lenet_exe
    dev = jax.local_devices()[0]
    bound = exe.bind(dev)
    assert bound.device == dev and exe.device is None
    ref = np.asarray(exe.run_per_frame(frames28))
    np.testing.assert_array_equal(np.asarray(bound.run_per_frame(frames28)),
                                  ref)
    np.testing.assert_array_equal(np.asarray(bound.run(frames28[:1])),
                                  np.asarray(exe.run(frames28[:1])))
    # padded path twice: the second run reuses the staging buffer and the
    # cached device params, still bitwise equal
    a = np.asarray(bound.run_padded(frames28[:3], bucket=4))
    b = np.asarray(bound.run_padded(frames28[:3], bucket=4))
    np.testing.assert_array_equal(a, ref[:3])
    np.testing.assert_array_equal(b, ref[:3])
    assert len(bound._staging) == 1


def test_staging_ring_survives_pipelined_dispatch(lenet_exe, frames28):
    """run_padded must never rewrite a staging buffer an async-dispatched
    batch may still read (jax.device_put of numpy need not copy
    synchronously). The bound view rotates `staging_slots` buffers, so
    the pool worker's pipeline order — dispatch batch N+1, then await
    batch N — stays bit-identical."""
    _, exe = lenet_exe
    bound = exe.bind(jax.local_devices()[0], staging_slots=2)
    ref_a = np.asarray(exe.run_per_frame(frames28[:3]))
    ref_b = np.asarray(exe.run_per_frame(frames28[3:6]))
    # dispatch two padded batches back-to-back; materialize only after
    # both have staged (the max_inflight=2 worker interleaving)
    lazy_a = bound.run_padded(frames28[:3], bucket=4)
    lazy_b = bound.run_padded(frames28[3:6], bucket=4)
    np.testing.assert_array_equal(np.asarray(lazy_a), ref_a)
    np.testing.assert_array_equal(np.asarray(lazy_b), ref_b)
    # one (bucket, shape) key, two rotated distinct buffers behind it
    (ring,) = bound._staging.values()
    assert len(ring) == 2
    assert not np.shares_memory(ring[0], ring[1])
    with pytest.raises(ValueError, match="staging_slots"):
        exe.bind(jax.local_devices()[0], staging_slots=0)


def test_bind_donate_bit_identical(lenet_exe, frames28):
    """Buffer donation (off by default on CPU, where XLA can't use it)
    must not change results — the frames each request keeps are copies
    of caller data, so donating the padded staging batch is safe."""
    _, exe = lenet_exe
    dev = jax.local_devices()[0]
    assert exe.bind(dev)._donate == (jax.default_backend() != "cpu")
    ref = np.asarray(exe.run_per_frame(frames28[:4]))
    with warnings.catch_warnings():
        # CPU XLA warns that donated buffers were unusable; that is the
        # reason donation defaults off on CPU — forcing it on here only
        # checks the result contract
        warnings.simplefilter("ignore")
        donating = exe.bind(dev, donate=True)
        out = np.asarray(donating.run_per_frame(frames28[:4].copy()))
    np.testing.assert_array_equal(out, ref)


def test_server_devices_exceeding_local_raises(lenet_exe):
    prog, _ = lenet_exe
    server = serve.Server(serve.ServeConfig(devices=N_DEVICES + 1))
    server.register("lenet", prog, REFERENCE)
    with pytest.raises(ValueError, match="local device"):
        server.start()


# -- the property suite: 4-virtual-device bit-identity ------------------------

@needs4
@pytest.mark.parametrize("placement", ["least_loaded", "round_robin"])
def test_pool_dispatch_bit_identity_property(placement):
    """Satellite property test: random programs x batch sizes x bucket
    ladders, interleaved across a 4-virtual-device pool, must be bitwise
    equal to direct single-device run_per_frame."""
    rng = np.random.default_rng(11)
    lenet = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    edge = repro.Program.from_pipeline("edge_detect", 16, 16, 3)
    sharpen = repro.Program.from_pipeline("sharpen", 16, 16, 3)
    server = serve.Server(serve.ServeConfig(
        max_batch=8, max_wait_ms=1.0, devices=4, placement=placement))
    specs = {
        "lenet": (server.register("lenet", lenet, REFERENCE,
                                  buckets=(1, 2, 4, 8)), (28, 28, 1)),
        "edge": (server.register("edge", edge, REFERENCE,
                                 buckets=(2, 8)), (16, 16, 3)),
        "sharpen": (server.register("sharpen", sharpen, REFERENCE,
                                    buckets=(1, 3, 5)), (16, 16, 3)),
    }
    server.start()
    try:
        subs = []
        for _ in range(30):                 # interleaved multi-program mix
            name = ("lenet", "edge", "sharpen")[rng.integers(3)]
            hosted, hwc = specs[name]
            n = int(rng.integers(1, 7))     # odd sizes exercise padding
            f = rng.random((n, *hwc), np.float32)
            subs.append((hosted, f, server.submit(name, f)))
        for hosted, f, fut in subs:
            got = np.asarray(fut.result(timeout=300))
            want = np.asarray(hosted.executable.run_per_frame(f))
            np.testing.assert_array_equal(got, want)
        st = server.stats()
        pool = st["pool"]
        assert pool["devices"] == 4
        used = [d["device"] for d in pool["per_device"] if d["batches"]]
        assert len(used) >= 2, f"pool never spread load: {pool}"
        assert st["requests"]["served"] == 30
    finally:
        server.stop()
    assert all(d["inflight_frames"] == 0 and d["queued_frames"] == 0
               for d in server.stats()["pool"]["per_device"])


@needs4
def test_pool_matches_single_device_server_bitwise(frames28):
    """The same traffic through a devices=4 server and a devices=1 server
    resolves to identical bytes — the pool is invisible to results."""
    prog = repro.Program.from_model("lenet", key=jax.random.PRNGKey(0))
    outs = {}
    for ndev in (1, 4):
        server = serve.Server(serve.ServeConfig(max_batch=4, max_wait_ms=0.5,
                                                devices=ndev))
        server.register("lenet", prog, REFERENCE)
        server.start()
        try:
            futs = [server.submit("lenet", frames28[i % 9][None])
                    for i in range(16)]
            outs[ndev] = [np.asarray(f.result(timeout=300)) for f in futs]
        finally:
            server.stop()
    for a, b in zip(outs[1], outs[4]):
        np.testing.assert_array_equal(a, b)
