"""End-to-end driver tests: train loop learns, QAT runs, serving generates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.train import main as train_main
from repro.launch.serve import main as serve_main

pytestmark = pytest.mark.slow


def test_train_loss_decreases(tmp_path):
    losses = train_main([
        "--arch", "smollm-360m", "--smoke", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "2e-3",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ])
    assert len(losses) == 30
    # planted bigram structure is learnable: clear loss drop
    assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])


def test_train_restart_resumes(tmp_path):
    train_main(["--arch", "tinyllama-1.1b", "--smoke", "--steps", "10",
                "--batch", "4", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"])
    # resume: should do only the remaining steps (5 already checkpointed)
    losses = train_main(["--arch", "tinyllama-1.1b", "--smoke", "--steps",
                         "12", "--batch", "4", "--seq", "32",
                         "--ckpt-dir", str(tmp_path), "--ckpt-every", "5"])
    assert len(losses) == 2                   # 10 -> 12


def test_qat_training_runs(tmp_path):
    losses = train_main([
        "--arch", "smollm-360m", "--smoke", "--steps", "10",
        "--batch", "4", "--seq", "32", "--quant", "w4a4",
        "--ckpt-dir", str(tmp_path),
    ])
    assert all(np.isfinite(l) for l in losses)


def test_serve_generates():
    toks = serve_main(["--arch", "smollm-360m", "--smoke", "--batch", "2",
                       "--prompt-len", "8", "--gen", "4"])
    assert toks.shape == (2, 12)


def test_serve_quantized():
    toks = serve_main(["--arch", "tinyllama-1.1b", "--smoke", "--batch", "2",
                       "--prompt-len", "4", "--gen", "4", "--quant", "w4a4"])
    assert toks.shape == (2, 8)
