"""Checkpoint atomicity / keep-k / resharding tests."""

import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"layers": {"w": jax.random.normal(k1, (8, 4)),
                       "b": jnp.zeros(4)},
            "step": jnp.asarray(7, jnp.int32),
            "nested": [jax.random.normal(k2, (3,))]}


def test_save_restore_roundtrip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 10, tree)
    assert latest_step(tmp_path) == 10
    target = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                          tree)
    out = restore_checkpoint(tmp_path, target)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_partial_checkpoints(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, 1, tree)
    # simulate a crashed writer: stale tmp dir must not count as a checkpoint
    stale = tmp_path / "tmp.99.12345"
    stale.mkdir()
    (stale / "proc_0.npz").write_bytes(b"garbage")
    assert latest_step(tmp_path) == 1
    mgr = CheckpointManager(tmp_path, keep=2, save_interval_steps=1)
    mgr.save(2, tree, force=True)
    assert not any(p.name.startswith("tmp.") for p in tmp_path.iterdir())


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, save_interval_steps=1)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree, force=True)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]


def test_restore_detects_shape_mismatch(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.zeros((4, 4))})
    with pytest.raises(ValueError):
        restore_checkpoint(tmp_path, {"w": jax.ShapeDtypeStruct((8, 4),
                                                                jnp.float32)})
    with pytest.raises(KeyError):
        restore_checkpoint(tmp_path, {"v": jax.ShapeDtypeStruct((4, 4),
                                                                jnp.float32)})


def test_restore_with_dtype_cast(tmp_path):
    save_checkpoint(tmp_path, 1, {"w": jnp.ones((4,), jnp.float32)})
    out = restore_checkpoint(
        tmp_path, {"w": jax.ShapeDtypeStruct((4,), jnp.bfloat16)})
    assert out["w"].dtype == jnp.bfloat16


def test_manifest_contents(tmp_path):
    save_checkpoint(tmp_path, 3, _tree(), extra={"arch": "smollm-360m"})
    man = json.load(open(tmp_path / "step_00000003" / "manifest.json"))
    assert man["step"] == 3
    assert man["extra"]["arch"] == "smollm-360m"
    assert "layers/w" in man["leaves"]


def test_restore_with_shardings_single_device(tmp_path):
    """Resharding path: restore onto an explicit (1-device) mesh sharding."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    save_checkpoint(tmp_path, 5, tree)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shard = {"w": NamedSharding(mesh, P("data", None))}
    out = restore_checkpoint(
        tmp_path, {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)},
        shardings=shard)
    np.testing.assert_array_equal(np.asarray(out["w"]),
                                  np.arange(16.0).reshape(4, 4))
    assert out["w"].sharding.is_equivalent_to(shard["w"], 2)
