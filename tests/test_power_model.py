"""Power model tests: the paper's Table-1 / Fig. 8-9 claims as assertions."""

import pytest

from repro.core import optical_core as oc
from repro.core.power_model import (PowerModel, CROSSLIGHT_PROFILE,
                                    LIGHTBULB_PROFILE)
from repro.core.quant import W4A4, W3A4, W2A4, MX_43, MX_42
from repro.models.vision import vgg9_ir, vision_schedules


@pytest.fixture(scope="module")
def vgg9_scheds():
    return vision_schedules(vgg9_ir(use_ca=True, n_classes=100), 32)


def test_table1_power_calibration(vgg9_scheds):
    """Lightator rows of Table 1 (tolerance: model-level reproduction)."""
    pm = PowerModel()
    targets = {  # scheme -> (paper max power W, paper kFPS/W)
        "44": (W4A4, 5.28, 61.61),
        "34": (W3A4, 2.71, 117.65),
        "24": (W2A4, 1.46, 188.24),
        "mx43": (MX_43, 3.64, 84.4),
    }
    for name, (scheme, p_ref, k_ref) in targets.items():
        r = pm.model_report(vgg9_scheds, scheme)
        # power within 20% of the paper's figure (avg or max)
        best = min(abs(r.max_power_w - p_ref), abs(r.avg_power_w - p_ref))
        assert best / p_ref < 0.20, (name, r.max_power_w, r.avg_power_w, p_ref)
        assert abs(r.kfps_per_w - k_ref) / k_ref < 0.25, (name, r.kfps_per_w)


def test_dac_dominates_power(vgg9_scheds):
    """Fig. 9: DACs contribute >85% of total power (weight-tuning path)."""
    pm = PowerModel()
    r = pm.model_report(vgg9_scheds, W3A4)
    comps = r.component_totals()
    assert comps["DAC"] / sum(comps.values()) > 0.85


def test_weight_bit_reduction_power_ratio(vgg9_scheds):
    """~2x power saving per weight bit (paper: 2.4x avg across Fig. 8)."""
    pm = PowerModel()
    p4 = pm.model_report(vgg9_scheds, W4A4).avg_power_w
    p3 = pm.model_report(vgg9_scheds, W3A4).avg_power_w
    p2 = pm.model_report(vgg9_scheds, W2A4).avg_power_w
    assert 1.6 < p4 / p3 < 2.6
    assert 1.6 < p3 / p2 < 2.6


def test_adc_reliant_baseline_burns_more(vgg9_scheds):
    """Prior designs (act-in-MRs + ADC readout) cost much more power."""
    ours = PowerModel().model_report(vgg9_scheds, W4A4).avg_power_w
    cross = PowerModel(profile=CROSSLIGHT_PROFILE).model_report(
        vgg9_scheds, W4A4).avg_power_w
    bulb = PowerModel(profile=LIGHTBULB_PROFILE).model_report(
        vgg9_scheds, W4A4).avg_power_w
    assert cross > ours * 2
    assert bulb > ours * 2


def test_mx_rail_monotonicity(vgg9_scheds):
    pm = PowerModel()
    p34 = pm.model_report(vgg9_scheds, W3A4).avg_power_w
    pmx = pm.model_report(vgg9_scheds, MX_43).avg_power_w
    p44 = pm.model_report(vgg9_scheds, W4A4).avg_power_w
    assert p34 < pmx < p44
    p24 = pm.model_report(vgg9_scheds, W2A4).avg_power_w
    pmx2 = pm.model_report(vgg9_scheds, MX_42).avg_power_w
    assert p24 < pmx2 < p44


def test_ca_reduces_first_layer_power():
    """Fig. 9 claim: CA compression cuts first-layer power (42.2% there)."""
    pm = PowerModel()
    with_ca = vision_schedules(vgg9_ir(use_ca=True), 32)
    no_ca = vision_schedules(vgg9_ir(use_ca=False), 32)
    r_ca = pm.model_report(with_ca, W3A4)
    r_no = pm.model_report(no_ca, W3A4)
    l1_ca = next(l for l in r_ca.layers if l.name == "conv1")
    l1_no = next(l for l in r_no.layers if l.name == "conv1")
    reduction = 1 - l1_ca.total_w / l1_no.total_w
    assert reduction > 0.3, reduction    # we measure ~66%; paper reports 42.2%
